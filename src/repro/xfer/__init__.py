"""``repro.xfer`` - the striped, pipelined transfer plane.

The hot path of every submit, restore and heal: one staging pass, blobs
striped into fixed-size chunks round-robin across the partner ring (the
paper's Sec. V message splitting), a double-buffered async stager whose
``drain()`` barrier the session and the recovery window share, verified-
exact delta encoding between close submits, and on-device digest
verification through the fused Pallas checksum kernel.

Consumers: ``repro.store`` (all three levels + the RecoveryLadder),
``repro.heal.Healer`` (clone staging + verification), ``ServeEngine`` KV
snapshots, and ``core.state_transfer.verify_clone``.
"""
from repro.xfer.chunking import (
    Chunk,
    ChunkedBlob,
    LeafSpec,
    PagedBlob,
    chunk_blob,
    chunk_count,
    chunk_pages,
    layout_from_json,
    layout_to_json,
    size_for_chunks,
    stripe_holders,
)
from repro.xfer.delta import (
    DeltaEncoder,
    decode_delta,
    encode_delta,
    payload_from_parts,
    payload_parts,
)
from repro.xfer.deadline import Deadline, DeadlineExceeded, backoff_delays
from repro.xfer.digest import digests_match, tree_digests, verify_tree
from repro.xfer.plane import (
    DEFAULT_CHUNK_BYTES,
    AsyncStager,
    TransferPlane,
    capture_tree,
    stage_tree,
)

__all__ = [
    "AsyncStager",
    "Chunk",
    "ChunkedBlob",
    "DEFAULT_CHUNK_BYTES",
    "Deadline",
    "DeadlineExceeded",
    "DeltaEncoder",
    "backoff_delays",
    "LeafSpec",
    "PagedBlob",
    "TransferPlane",
    "capture_tree",
    "chunk_blob",
    "chunk_count",
    "chunk_pages",
    "decode_delta",
    "digests_match",
    "encode_delta",
    "layout_from_json",
    "layout_to_json",
    "payload_from_parts",
    "payload_parts",
    "size_for_chunks",
    "stage_tree",
    "stripe_holders",
    "tree_digests",
    "verify_tree",
]
