"""TransferPlane - the shared fast path of every submit, restore and heal.

PartRePer-MPI's failure-free overhead stays low because state movement to
replicas is *parallel and overlapped* (Sec. V: message splitting +
communication strategies), not a serial whole-blob stop-the-world copy.
This object owns that machinery once, for every consumer (the recovery
ladder's store levels, the healer's clone staging, serving KV snapshots):

- **staging** (:func:`stage_tree`) - the one device->host flatten pass a
  submit pays, shared by all blob/chunk-consuming levels;
- **striping** (:meth:`chunked`) - the staged blob cut into fixed-size
  chunks (memoized per staged blob, so several chunk-consuming stores
  share one cut);
- **delta encoding** (:meth:`delta_encoder`) - per-consumer verified-
  exact delta state (``xfer.delta``);
- **pipelining** (:class:`AsyncStager`) - a double-buffered background
  worker that overlaps staging + placement with the next train step;
  :meth:`drain` is the barrier ``FTSession.run`` and the recovery window
  reuse before they need the snapshots to be real.

Mutable host leaves (plain ``np.ndarray``) are captured synchronously by
:func:`capture_tree` before a submit goes asynchronous - the
capture-before-return contract survives pipelining. Device arrays are
immutable and cross the queue by reference; programs that donate their
step buffers should construct the plane with ``pipeline=False``.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.dist.sharding import path_str
from repro.xfer.chunking import (
    ChunkedBlob,
    PagedBlob,
    chunk_blob,
    chunk_count,
    chunk_pages,
    size_for_chunks,
)
from repro.xfer.delta import DeltaEncoder

PyTree = Any

#: default stripe size: 1 MiB (NIC-message sized; small states stripe
#: down further so every ring member holds a part)
DEFAULT_CHUNK_BYTES = 1 << 20


def stage_tree(tree: PyTree, *, copy: bool = True) -> Dict[str, np.ndarray]:
    """Flatten a pytree to ``{path: host ndarray}`` - THE staging pass.
    Every leaf is a fresh host copy: device arrays via the device->host
    transfer, numpy leaves via an explicit copy (``np.asarray`` alone
    would alias the caller's buffer, breaking the capture-before-return
    contract for programs that mutate state in place). ``copy=False``
    skips the ndarray copy for trees ALREADY privately owned (the async
    path stages a :func:`capture_tree` result - copying it again would
    double the memcpy on the hot path).

    A :class:`PagedBlob` is already staged: its entries are sealed host
    pages the producer never mutates, so the pass is a shallow rebind -
    the whole point of the paged layout is that submits stop paying a
    per-tick copy of the unchanged state."""
    if isinstance(tree, PagedBlob):
        return PagedBlob(tree)
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {
        path_str(kp): (
            np.array(leaf) if copy and isinstance(leaf, np.ndarray)
            else np.asarray(leaf)
        )
        for kp, leaf in flat
    }


def capture_tree(tree: PyTree) -> PyTree:
    """The cheap synchronous half of an async submit: copy the MUTABLE
    leaves (host ndarrays a program may overwrite in place) now; immutable
    leaves (device arrays, scalars) cross to the stager by reference.
    Sealed pages in a :class:`PagedBlob` are immutable by contract and
    cross by reference too."""
    if isinstance(tree, PagedBlob):
        return PagedBlob(tree)
    return jax.tree.map(
        lambda x: np.array(x) if isinstance(x, np.ndarray) else x, tree
    )


class AsyncStager:
    """Bounded background executor: at most ``depth`` submits in flight
    (double-buffered by default), FIFO, one worker - so delta references
    and store placements observe submits in order. Errors surface on the
    next :meth:`submit` or :meth:`drain` rather than dying on the daemon
    thread."""

    def __init__(self, depth: int = 2):
        assert depth >= 1
        self.depth = depth
        self._q: deque = deque()
        self._cv = threading.Condition()
        self._inflight = 0  # queued + running
        self._err: Optional[BaseException] = None
        self._worker: Optional[threading.Thread] = None

    def submit(self, fn) -> None:
        """Enqueue ``fn``; blocks only while ``depth`` submits are already
        in flight (the bounded-memory backpressure)."""
        with self._cv:
            self._raise_locked()
            while self._inflight >= self.depth:
                self._cv.wait()
                self._raise_locked()
            self._q.append(fn)
            self._inflight += 1
            if self._worker is None or not self._worker.is_alive():
                self._worker = threading.Thread(target=self._loop, daemon=True)
                self._worker.start()
            self._cv.notify_all()

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Barrier: every enqueued submit has fully executed. With a
        ``timeout`` the barrier is *bounded* - a wedged background submit
        (the gray-failure case) returns False after ~timeout seconds
        instead of blocking the recovery window forever; the caller
        decides whether a stale snapshot level is survivable. Returns
        True when fully drained."""
        with self._cv:
            if timeout is None:
                while self._inflight:
                    self._cv.wait()
            else:
                t_end = time.monotonic() + timeout
                while self._inflight:
                    left = t_end - time.monotonic()
                    if left <= 0:
                        return False
                    self._cv.wait(timeout=left)
            self._raise_locked()
            return True

    @property
    def inflight(self) -> int:
        with self._cv:
            return self._inflight

    def _raise_locked(self) -> None:
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._q:
                    self._cv.wait()
                fn = self._q.popleft()
            try:
                fn()
            except BaseException as e:  # noqa: BLE001 - reraised on drain
                with self._cv:
                    if self._err is None:  # keep the ROOT CAUSE, not the
                        self._err = e      # consequent failures after it
            finally:
                with self._cv:
                    self._inflight -= 1
                    self._cv.notify_all()


class TransferPlane:
    def __init__(
        self,
        *,
        chunk_bytes: int = DEFAULT_CHUNK_BYTES,
        delta: str = "none",
        pipeline: bool = True,
        depth: int = 2,
    ):
        assert chunk_bytes >= 4 and chunk_bytes % 4 == 0, chunk_bytes
        self.chunk_bytes = chunk_bytes
        self.delta = delta
        self.pipeline = pipeline
        self.stager = AsyncStager(depth)
        self._memo_lock = threading.Lock()
        self._memo: Optional[tuple] = None  # (blob ref, size, ChunkedBlob)

    # ---- staging -----------------------------------------------------------
    def stage(self, state: PyTree) -> Dict[str, np.ndarray]:
        return stage_tree(state)

    # ---- striping ----------------------------------------------------------
    def chunked(self, blob: Dict[str, np.ndarray], *, min_chunks: int = 1
                ) -> ChunkedBlob:
        """Cut ``blob`` into stripes. ``min_chunks`` lets a consumer ask
        for at least its ring size, so every member holds a part even of a
        small state. Memoized on the blob identity: chunk-consuming stores
        fed the same staged blob share one cut. A :class:`PagedBlob` gets
        the page cut - its pages ARE the chunks, whatever ``min_chunks``
        (striping spreads them round-robin regardless of count)."""
        if isinstance(blob, PagedBlob):
            with self._memo_lock:
                if self._memo is not None:
                    mblob, _, mcb = self._memo
                    if mblob is blob:
                        return mcb
            cb = chunk_pages(blob)
            with self._memo_lock:
                self._memo = (blob, cb.chunk_bytes, cb)
            return cb
        total = sum(a.nbytes for a in blob.values())
        n = chunk_count(total, self.chunk_bytes, min_chunks)
        size = min(self.chunk_bytes, size_for_chunks(total, n))
        with self._memo_lock:
            if self._memo is not None:
                mblob, msize, mcb = self._memo
                if mblob is blob and msize == size:
                    return mcb
        cb = chunk_blob(blob, size)
        with self._memo_lock:
            self._memo = (blob, size, cb)
        return cb

    def chunked_cached(self, blob: Dict[str, np.ndarray], *,
                       min_chunks: int = 1) -> ChunkedBlob:
        """The memoized cut for ``blob`` at WHATEVER chunk size it was cut
        (a consumer with no ring of its own - the durable level - adopts
        the granularity the level before it striped at, sharing one pass
        and keeping sub-block delta reuse meaningful for states smaller
        than one default chunk); falls back to a fresh cut."""
        with self._memo_lock:
            if self._memo is not None and self._memo[0] is blob:
                return self._memo[2]
        return self.chunked(blob, min_chunks=min_chunks)

    # ---- delta -------------------------------------------------------------
    def delta_encoder(self) -> DeltaEncoder:
        """A fresh per-consumer delta state (stores own their reference
        lifetime - it matches their ring, not the plane)."""
        return DeltaEncoder(self.delta)

    # ---- pipelining --------------------------------------------------------
    def submit_async(self, fn) -> None:
        self.stager.submit(fn)

    def drain(self, timeout: Optional[float] = None) -> bool:
        return self.stager.drain(timeout)
